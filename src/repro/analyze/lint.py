"""Custom AST lint: keep code on the registry/ledger/validator rails.

The serve stack's discipline is architectural, not syntactic — every
kernel launch goes through the dispatch funnels (which resolve a tile
from the registry, record to the GEMM ledger and preflight-validate),
library validation raises typed errors instead of ``assert`` (which
vanishes under ``python -O``), fault injection must propagate, and
process-global state mutates under its module lock.  None of that is
enforceable by stock linters, so this pass encodes it as five rules:

========  ============================================================
code      invariant
========  ============================================================
RPR001    kernel entrypoints (``ca_gemm_program``, ``fused_matmul``,
          ``quant_matmul``, flash attention, ...) are only called from
          the dispatch layers (``repro/core``, ``repro/kernels``,
          ``repro/tuning``, ``repro/kvcache``) — everything else goes
          through the registry-backed funnels
RPR002    a dispatch-layer function that launches a kernel must touch
          the GEMM ledger (``record_gemm`` / ``_ledger`` / ...) or be
          explicitly suppressed with a comment saying who records
RPR003    no ``assert``-based validation in library code: asserts in
          ``__init__``/``__post_init__`` or in the leading check block
          of a public function must be raised errors
RPR004    no ``except:`` and no ``except Exception`` whose handler
          neither re-raises nor routes through a re-raise guard
          (``_note_fallback``) — both swallow
          ``InjectedKernelFailure`` and validator fatals
RPR005    a function that rebinds a module global (``global x; x = ..``)
          must do so inside a ``with <lock>:`` block
========  ============================================================

Suppress a finding with an inline ``# repro: noqa`` (all codes) or
``# repro: noqa RPR001`` / ``# repro: noqa RPR001,RPR004`` on the
flagged line.  ``python -m repro.analyze lint <paths> --format json``
emits the machine-readable report CI archives.
"""

from __future__ import annotations

import ast
import dataclasses
import json
import pathlib
import re
import sys
from typing import Dict, Iterable, List, Optional, Sequence, Set, Tuple

RULES: Dict[str, str] = {
    "RPR001": "kernel entrypoint called outside the dispatch layers "
              "(registry bypass)",
    "RPR002": "dispatch function launches a kernel without a ledger "
              "record",
    "RPR003": "assert-based validation in library code (vanishes under "
              "python -O)",
    "RPR004": "bare/overbroad except that can swallow "
              "InjectedKernelFailure without re-raising",
    "RPR005": "module-global rebound outside a lock",
}

# The raw kernel entrypoints the dispatch funnels wrap.
KERNEL_ENTRYPOINTS = frozenset({
    "ca_gemm_program", "ca_mmm_k_outer", "fused_matmul", "glu_matmul",
    "quant_matmul", "quant_glu_matmul", "flash_attention_tpu",
    "paged_flash_attention_tpu",
})

# repro subpackages allowed to call entrypoints directly (RPR001) ...
_DISPATCH_DIRS = frozenset({"core", "kernels", "tuning", "kvcache"})
# ... and the subset that must also record to the ledger (RPR002).
_LEDGER_DIRS = frozenset({"core", "kvcache"})
_LEDGER_NAMES = frozenset({
    "record_gemm", "record_attention", "record_dist", "_record_dist",
    "_ledger", "get_ledger",
})
_RERAISE_GUARDS = frozenset({"_note_fallback"})

_NOQA_RE = re.compile(
    r"#\s*repro:\s*noqa(?:\s+(?P<codes>RPR[0-9]{3}(?:\s*,\s*RPR[0-9]{3})*))?")


@dataclasses.dataclass(frozen=True)
class Finding:
    path: str
    line: int
    code: str
    message: str

    def __str__(self) -> str:
        return f"{self.path}:{self.line}: {self.code} {self.message}"

    def to_json(self) -> Dict:
        return dataclasses.asdict(self)


def _noqa_for_line(lines: Sequence[str], lineno: int) -> Optional[Set[str]]:
    """Suppression on source line ``lineno`` (1-based): ``set()`` means
    all codes, a non-empty set names specific ones, None means no noqa."""
    if not 1 <= lineno <= len(lines):
        return None
    m = _NOQA_RE.search(lines[lineno - 1])
    if not m:
        return None
    codes = m.group("codes")
    if not codes:
        return set()
    return {c.strip() for c in codes.split(",")}


def _call_name(node: ast.Call) -> Optional[str]:
    if isinstance(node.func, ast.Name):
        return node.func.id
    if isinstance(node.func, ast.Attribute):
        return node.func.attr
    return None


def _walk_own(fn: ast.AST) -> Iterable[ast.AST]:
    """Walk a function's body without descending into nested defs."""
    stack = list(ast.iter_child_nodes(fn))
    while stack:
        node = stack.pop()
        yield node
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.Lambda, ast.ClassDef)):
            continue
        stack.extend(ast.iter_child_nodes(node))


def _path_parts(path: pathlib.Path) -> Tuple[str, ...]:
    return tuple(p for p in path.parts if p not in (".", ".."))


def _repro_subpackage(path: pathlib.Path) -> Optional[str]:
    """The subpackage directly under ``repro/`` (or None outside it)."""
    parts = _path_parts(path)
    if "repro" not in parts:
        return None
    idx = parts.index("repro")
    if idx + 1 >= len(parts):
        return None
    nxt = parts[idx + 1]
    return None if nxt.endswith(".py") else nxt


def _assert_exempt(path: pathlib.Path) -> bool:
    """RPR003 skips internal tooling modules (``_stubs/``, ``_x.py``)."""
    return any(p.startswith("_") and p != "__init__.py"
               for p in _path_parts(path))


class _Linter:
    def __init__(self, path: pathlib.Path, tree: ast.Module):
        self.path = path
        self.tree = tree
        self.findings: List[Finding] = []

    def flag(self, code: str, node: ast.AST, message: str) -> None:
        self.findings.append(Finding(path=str(self.path),
                                     line=getattr(node, "lineno", 0),
                                     code=code, message=message))

    def run(self) -> List[Finding]:
        sub = _repro_subpackage(self.path)
        self._rule_calls(sub)
        self._rule_excepts()
        for node in ast.walk(self.tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                if not _assert_exempt(self.path):
                    self._rule_asserts(node)
                self._rule_globals(node)
                if sub in _LEDGER_DIRS:
                    self._rule_ledger(node)
        return self.findings

    # -- RPR001 ----------------------------------------------------------
    def _rule_calls(self, sub: Optional[str]) -> None:
        if sub in _DISPATCH_DIRS:
            return
        for node in ast.walk(self.tree):
            if isinstance(node, ast.Call):
                name = _call_name(node)
                if name in KERNEL_ENTRYPOINTS:
                    self.flag("RPR001", node,
                              f"direct call to kernel entrypoint "
                              f"{name!r} bypasses the registry dispatch "
                              "funnel")

    # -- RPR002 ----------------------------------------------------------
    def _rule_ledger(self, fn: ast.AST) -> None:
        launches = None
        records = False
        for node in _walk_own(fn):
            if isinstance(node, ast.Call) and \
                    _call_name(node) in KERNEL_ENTRYPOINTS:
                launches = launches or node
            if isinstance(node, ast.Name) and node.id in _LEDGER_NAMES:
                records = True
            if isinstance(node, ast.Attribute) and \
                    node.attr in _LEDGER_NAMES:
                records = True
        if launches is not None and not records:
            self.flag("RPR002", fn,
                      f"function {fn.name!r} launches a kernel but never "
                      "touches the GEMM ledger (record_gemm/_ledger)")

    # -- RPR003 ----------------------------------------------------------
    def _rule_asserts(self, fn: ast.AST) -> None:
        if fn.name in ("__init__", "__post_init__"):
            for node in _walk_own(fn):
                if isinstance(node, ast.Assert):
                    self.flag("RPR003", node,
                              f"assert validation in {fn.name!r} — raise "
                              "ValueError/ProgramValidationError instead")
            return
        if fn.name.startswith("_"):
            return
        body = list(fn.body)
        if body and isinstance(body[0], ast.Expr) and \
                isinstance(body[0].value, ast.Constant) and \
                isinstance(body[0].value.value, str):
            body = body[1:]  # docstring
        for stmt in body:
            if not isinstance(stmt, ast.Assert):
                break
            self.flag("RPR003", stmt,
                      f"leading assert validation in public "
                      f"{fn.name!r} — raise a typed error instead")

    # -- RPR004 ----------------------------------------------------------
    def _rule_excepts(self) -> None:
        for node in ast.walk(self.tree):
            if not isinstance(node, ast.ExceptHandler):
                continue
            if node.type is None:
                self.flag("RPR004", node,
                          "bare 'except:' swallows everything, including "
                          "InjectedKernelFailure and validator fatals")
                continue
            if isinstance(node.type, ast.Name) and \
                    node.type.id in ("Exception", "BaseException"):
                handled = False
                for sub in ast.walk(node):
                    if isinstance(sub, ast.Raise):
                        handled = True
                    if isinstance(sub, ast.Call) and \
                            _call_name(sub) in _RERAISE_GUARDS:
                        handled = True
                if not handled:
                    self.flag("RPR004", node,
                              f"'except {node.type.id}' neither re-raises "
                              "nor routes through a re-raise guard "
                              f"({', '.join(sorted(_RERAISE_GUARDS))})")

    # -- RPR005 ----------------------------------------------------------
    def _rule_globals(self, fn: ast.AST) -> None:
        declared: Set[str] = set()
        for stmt in fn.body:
            if isinstance(stmt, ast.Global):
                declared.update(stmt.names)
        if not declared:
            return
        self._scan_global_writes(fn.body, declared, in_with=False)

    def _scan_global_writes(self, stmts, declared: Set[str],
                            in_with: bool) -> None:
        for stmt in stmts:
            targets: List[ast.expr] = []
            if isinstance(stmt, ast.Assign):
                targets = stmt.targets
            elif isinstance(stmt, (ast.AugAssign, ast.AnnAssign)):
                targets = [stmt.target]
            for t in targets:
                names = [t] if isinstance(t, ast.Name) else [
                    e for e in ast.walk(t) if isinstance(e, ast.Name)]
                for nm in names:
                    if nm.id in declared and not in_with:
                        self.flag("RPR005", stmt,
                                  f"module global {nm.id!r} rebound "
                                  "outside a 'with <lock>:' block")
            if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef,
                                 ast.ClassDef)):
                continue
            if isinstance(stmt, (ast.With, ast.AsyncWith)):
                self._scan_global_writes(stmt.body, declared, in_with=True)
                continue
            for field in ("body", "orelse", "finalbody", "handlers"):
                sub = getattr(stmt, field, None)
                if sub:
                    if field == "handlers":
                        for h in sub:
                            self._scan_global_writes(h.body, declared,
                                                     in_with)
                    else:
                        self._scan_global_writes(sub, declared, in_with)


def lint_source(path, source: str) -> Tuple[List[Finding], List[Finding]]:
    """Lint one file's source; returns (findings, suppressed)."""
    path = pathlib.Path(path)
    try:
        tree = ast.parse(source, filename=str(path))
    except SyntaxError as e:
        return ([Finding(path=str(path), line=e.lineno or 0,
                         code="RPR003",
                         message=f"file does not parse: {e.msg}")], [])
    all_findings = _Linter(path, tree).run()
    lines = source.splitlines()
    kept: List[Finding] = []
    suppressed: List[Finding] = []
    for f in all_findings:
        noqa = _noqa_for_line(lines, f.line)
        if noqa is not None and (not noqa or f.code in noqa):
            suppressed.append(f)
        else:
            kept.append(f)
    kept.sort(key=lambda f: (f.path, f.line, f.code))
    return kept, suppressed


def collect_files(paths: Sequence[str]) -> List[pathlib.Path]:
    files: List[pathlib.Path] = []
    for p in paths:
        path = pathlib.Path(p)
        if path.is_dir():
            files.extend(sorted(
                f for f in path.rglob("*.py")
                if "__pycache__" not in f.parts))
        elif path.suffix == ".py":
            files.append(path)
    return files


def lint_paths(paths: Sequence[str]
               ) -> Tuple[List[Finding], List[Finding], int]:
    """Lint files/dirs; returns (findings, suppressed, n_files)."""
    findings: List[Finding] = []
    suppressed: List[Finding] = []
    files = collect_files(paths)
    for f in files:
        kept, supp = lint_source(f, f.read_text())
        findings.extend(kept)
        suppressed.extend(supp)
    return findings, suppressed, len(files)


def main(argv: Optional[Sequence[str]] = None) -> int:
    import argparse

    ap = argparse.ArgumentParser(
        prog="python -m repro.analyze lint",
        description="AST lint for the repro serve-stack discipline "
                    "(rules RPR001-RPR005)")
    ap.add_argument("paths", nargs="+", help="files or directories")
    ap.add_argument("--format", choices=("text", "json"), default="text")
    ap.add_argument("--output", default=None,
                    help="write the report here as well as stdout")
    args = ap.parse_args(argv)

    findings, suppressed, n_files = lint_paths(args.paths)
    if args.format == "json":
        report = {
            "rules": RULES,
            "files": n_files,
            "findings": [f.to_json() for f in findings],
            "suppressed": [f.to_json() for f in suppressed],
        }
        text = json.dumps(report, indent=2, sort_keys=True)
    else:
        out = [str(f) for f in findings]
        out.append(f"{len(findings)} finding(s), {len(suppressed)} "
                   f"suppressed, {n_files} file(s)")
        text = "\n".join(out)
    print(text)
    if args.output:
        pathlib.Path(args.output).write_text(text + "\n")
    return 1 if findings else 0


if __name__ == "__main__":  # pragma: no cover
    sys.exit(main())

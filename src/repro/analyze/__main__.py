"""CLI entry: ``python -m repro.analyze {lint,report} ...``.

``lint`` runs the AST pass (see :mod:`repro.analyze.lint`); ``report``
is the static pre-deploy sweep — it resolves the config zoo's
representative GEMMs through the registry and verifies every plan,
without touching an accelerator.
"""

from __future__ import annotations

import sys
from typing import List, Optional, Sequence, Tuple


def _arch_gemms(cfg) -> List[Tuple[str, int, int, str]]:
    """(name, n, k, tag) for one arch's representative serve GEMMs."""
    d = cfg.d_model
    gemms: List[Tuple[str, int, int, str]] = []
    if cfg.attn_kind == "gqa":
        Dh = cfg.resolved_head_dim
        gemms.append(("qkv", (cfg.n_heads + 2 * cfg.n_kv_heads) * Dh, d,
                      "none"))
        gemms.append(("attn_out", d, cfg.n_heads * Dh, "none"))
    if cfg.ssm is not None:
        # SSM in/out projections (the family's dominant GEMMs).
        di = cfg.ssm.d_inner(d)
        n_in = (2 * di + 2 * cfg.ssm.n_groups * cfg.ssm.d_state
                + cfg.ssm.n_heads(d))
        gemms.append(("ssm_in", n_in, d, "none"))
        gemms.append(("ssm_out", d, di, "none"))
    if cfg.d_ff > 0:
        if cfg.act == "silu":
            gemms.append(("ffn_glu", cfg.d_ff, d,
                          "rms>glu.silu(none|none)"))
        else:
            gemms.append(("ffn_up", cfg.d_ff, d, f"rms>bias+{cfg.act}"))
        gemms.append(("ffn_down", d, cfg.d_ff, "none"))
    gemms.append(("lm_head", cfg.padded_vocab, d, "none"))
    return [(name, n, k, tag) for name, n, k, tag in gemms
            if n > 0 and k > 0]


def report(argv: Optional[Sequence[str]] = None) -> int:
    import argparse

    ap = argparse.ArgumentParser(
        prog="python -m repro.analyze report",
        description="Static dry-run over the config zoo: resolve each "
                    "arch's representative GEMMs and verify the plans.")
    ap.add_argument("--arch", action="append", default=None,
                    help="architecture name (repeatable; default: all)")
    ap.add_argument("--prefill-m", type=int, default=4096)
    ap.add_argument("--decode-m", type=int, default=128)
    args = ap.parse_args(argv)

    import jax.numpy as jnp

    from repro.analyze.validate import planned_tile_bytes, \
        validate_program
    from repro.configs import get_config, list_archs
    from repro.tuning import get_registry

    registry = get_registry()
    hw = registry.hw
    archs = args.arch or list_archs()
    budget = int(hw.vmem_bytes * 0.75)
    n_diags = 0
    print(f"# static plan report — hw={hw.name} "
          f"(VMEM budget {budget} B)")
    for arch in archs:
        cfg = get_config(arch)
        print(f"\n{arch} (d_model={cfg.d_model}, d_ff={cfg.d_ff})")
        for phase, m in (("decode", args.decode_m),
                         ("prefill", args.prefill_m)):
            for name, n, k, tag in _arch_gemms(cfg):
                res = registry.resolve_full(m, n, k, dtype=jnp.bfloat16,
                                            hw=hw, epilogue=tag)
                t = res.config
                need = planned_tile_bytes(tag, t, dtype=jnp.bfloat16)
                diags = validate_program(tag, t, hw, dtype=jnp.bfloat16)
                status = "OK" if not diags else \
                    ",".join(sorted({d.code for d in diags}))
                print(f"  {phase:7s} {name:9s} m={m:<5d} n={n:<6d} "
                      f"k={k:<6d} tile=({t.bm},{t.bn},{t.bk},{t.order}) "
                      f"src={res.source:8s} vmem={need:>9d}B {status}")
                for diag in diags:
                    n_diags += 1
                    print(f"           !! {diag}")
    print(f"\n{n_diags} diagnostic(s)")
    return 1 if n_diags else 0


def main(argv: Optional[Sequence[str]] = None) -> int:
    argv = list(sys.argv[1:] if argv is None else argv)
    if not argv or argv[0] in ("-h", "--help"):
        print(__doc__)
        print("subcommands: lint <paths> [--format json] | report "
              "[--arch NAME]")
        return 0
    cmd, rest = argv[0], argv[1:]
    if cmd == "lint":
        from repro.analyze.lint import main as lint_main

        return lint_main(rest)
    if cmd == "report":
        return report(rest)
    print(f"unknown subcommand {cmd!r} (want: lint | report)",
          file=sys.stderr)
    return 2


if __name__ == "__main__":
    sys.exit(main())

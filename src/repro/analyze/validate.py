"""The program verifier: static checks on resolved dispatch plans.

Each function returns a list of :class:`repro.analyze.diagnostics.
Diagnostic` — empty means the plan satisfies every hard constraint the
kernels assume.  The checks deliberately *mirror* the constructive
guarantees of ``tuning/space.py`` / ``kernels/ca_mmm.py``: the solver
and autotuner only emit feasible configs, but persisted cache entries,
hand-built tiles and schema drift can all smuggle an infeasible plan to
the dispatch funnel, where it would otherwise die as a Pallas lowering
error (or silently, under ``python -O``, as garbage).

Paper anchors: the VMEM capacity constraint is Eq. 9 (tile solve under
on-chip memory), the per-tile scale rules come from the drain-fused
dequant contract (docs/QUANT.md), ring divisibility from the Eq. 6 wire
volume derivation over ``tp * pods`` k-chunks (docs/DISTRIBUTED.md).
"""

from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple, Union

import jax.numpy as jnp

from repro.analyze.diagnostics import Diagnostic, error, warning
from repro.core.hardware import TARGETS, TpuTarget, V5E
from repro.core.io_model import TileConfig, tile_vmem_bytes

# The fraction of VMEM the tile solve budgets against — must track
# tuning/space.py's default or the verifier would reject what the solver
# planned (or bless what it refused).
DEFAULT_VMEM_FRACTION = 0.75

_VALID_ORDERS = ("k_inner", "k_outer")
_ATTN_ORDER = "attn"

# Short dtype names used by composite cache keys (quant_dtype_str).
_SHORT_ITEMSIZE = {"bf16": 2, "f16": 2, "f32": 4, "f64": 8, "int8": 1}


def _target_by_name(name: str) -> Optional[TpuTarget]:
    """Resolve a cache key's leading field: TARGETS is keyed by short
    alias ('v5e') but the registry mints keys with ``hw.name``
    ('tpu-v5e'), so accept either spelling."""
    hit = TARGETS.get(name)
    if hit is not None:
        return hit
    for hw in TARGETS.values():
        if hw.name == name:
            return hw
    return None


def _itemsize(dtype) -> int:
    """Itemsize of a jnp dtype or a (short or full) dtype name."""
    if isinstance(dtype, str):
        if dtype in _SHORT_ITEMSIZE:
            return _SHORT_ITEMSIZE[dtype]
        return jnp.dtype(dtype).itemsize
    return jnp.dtype(dtype).itemsize


def _is_int8(dtype) -> bool:
    if dtype is None:
        return False
    if isinstance(dtype, str):
        return dtype in ("int8", "int8w")
    return jnp.dtype(dtype) == jnp.dtype(jnp.int8)


# ---------------------------------------------------------------------------
# GEMM programs (TAG002 / VMEM001 / QNT003)
# ---------------------------------------------------------------------------

def planned_tile_bytes(tag: str, config: TileConfig, *,
                       dtype=jnp.bfloat16, dtype_b=None, dtype_a=None,
                       scale_block: int = 0) -> int:
    """The VMEM bytes a resolved plan claims (Eq. 9 left-hand side):
    double-buffered streams, accumulators, and the program's extra
    residents, at the kernel's effective ``bk``."""
    from repro.kernels.program import program_cost

    cost = program_cost(tag)
    itemsize_in = _itemsize(dtype)
    return tile_vmem_bytes(
        config.bm, config.bn, scale_block or config.bk, itemsize_in,
        acc_bytes=4,
        epilogue_mn_ops=cost.stream_mn,
        epilogue_bias=cost.has_bias,
        itemsize_b=_itemsize(dtype_b) if dtype_b is not None
        else itemsize_in,
        n_b=cost.n_b, n_out=cost.n_out,
        prologue_mk_ops=cost.prologue_mk,
        prologue_kn_ops=cost.prologue_kn,
        itemsize_a=_itemsize(dtype_a) if dtype_a is not None
        else itemsize_in)


def validate_program(tag: str,
                     config: Optional[TileConfig],
                     hw: TpuTarget = V5E,
                     *,
                     dtype=jnp.bfloat16,
                     dtype_b=None,
                     dtype_a=None,
                     semiring: str = "plus_times",
                     scale_block: int = 0,
                     act_block: int = 0,
                     vmem_fraction: float = DEFAULT_VMEM_FRACTION
                     ) -> List[Diagnostic]:
    """Verify one resolved GEMM program against its hard constraints.

    ``tag`` is the full program tag (prologue/combiner grammar included)
    the dispatch resolved under; ``config`` the tile it plans to launch
    (``None`` skips the VMEM check — tag/dtype-chain legality only).
    ``scale_block`` is the weight's per-tile scale block (0 =
    per-channel), ``act_block`` the per-k-tile activation scale block —
    both pin/constrain ``bk`` on the kernel path.
    """
    from repro.kernels.program import program_from_tag, program_tag

    diags: List[Diagnostic] = []

    # -- TAG002: the tag must parse, and parse canonically -----------------
    try:
        spec = program_from_tag(tag)
    except ValueError as e:
        diags.append(error("TAG002",
                           f"program tag {tag!r} does not parse: {e}",
                           tag=tag))
        return diags  # nothing downstream is well-defined
    round_trip = program_tag(spec)
    if round_trip != tag:
        diags.append(error(
            "TAG002",
            f"program tag {tag!r} is not canonical (round-trips to "
            f"{round_trip!r}) — cache keys minted from it would never "
            "hit the canonical entry", tag=tag, canonical=round_trip))

    # -- QNT003: dtype-chain legality --------------------------------------
    b_int8 = _is_int8(dtype_b)
    a_int8 = _is_int8(dtype_a)
    dequants = tuple(b.dequant for b in spec.branches)
    if b_int8 and any(d == "none" for d in dequants):
        diags.append(error(
            "QNT003",
            "int8 B operand but a branch has no dequant drain stage — "
            "the accumulator would be served unscaled",
            tag=tag, dequants=dequants))
    if a_int8:
        if not b_int8:
            diags.append(error(
                "QNT003",
                "int8 A stream without an int8 B operand — the "
                "int8 x int8 -> int32 MXU path needs both sides "
                "quantized", tag=tag))
        if any(d != "ab" for d in dequants):
            diags.append(error(
                "QNT003",
                "int8 A stream requires the 'ab' dequant stage on every "
                "branch (both scales apply at the drain)",
                tag=tag, dequants=dequants))

    # -- QNT003: scale-block alignment -------------------------------------
    if scale_block:
        if scale_block % hw.lane != 0:
            diags.append(error(
                "QNT003",
                f"per-tile weight scale block {scale_block} is not a "
                f"multiple of the lane width {hw.lane} — a streamed "
                "(bk, bn) block would straddle two scale rows",
                scale_block=scale_block, lane=hw.lane))
        if act_block and act_block != scale_block:
            diags.append(error(
                "QNT003",
                f"per-k-tile activation scale block {act_block} != "
                f"weight scale block {scale_block} — the kernel applies "
                "one fused scale per k-step partial",
                act_block=act_block, scale_block=scale_block))
    elif act_block and act_block % hw.lane != 0:
        diags.append(error(
            "QNT003",
            f"activation scale block {act_block} is not a multiple of "
            f"the lane width {hw.lane}", act_block=act_block,
            lane=hw.lane))

    # -- VMEM001: Eq. 9 capacity -------------------------------------------
    if config is not None:
        # Per-tile scales pin the kernel's k-step to the scale block
        # (kernels/ca_mmm.py), so that is the bk the budget must hold.
        eff_bk = scale_block or config.bk
        budget = int(hw.vmem_bytes * vmem_fraction)
        need = planned_tile_bytes(tag, config, dtype=dtype,
                                  dtype_b=dtype_b, dtype_a=dtype_a,
                                  scale_block=scale_block)
        if need > budget:
            diags.append(error(
                "VMEM001",
                f"tile ({config.bm}, {config.bn}, {eff_bk}) claims "
                f"{need} B of VMEM > budget {budget} B "
                f"({vmem_fraction:.2f} x {hw.vmem_bytes} B on {hw.name})",
                bm=config.bm, bn=config.bn, bk=eff_bk, bytes=need,
                budget=budget, hw=hw.name, tag=tag))
        if semiring == "min_plus":
            # The tropical kernel materializes the fp32 (bm, bk, bn)
            # broadcast of a[i,k] + b[k,j] before the min-reduce.
            bcast = config.bm * eff_bk * config.bn * 4
            if bcast > budget:
                diags.append(error(
                    "VMEM001",
                    f"min_plus broadcast buffer bm*bk*bn*4 = {bcast} B "
                    f"exceeds the VMEM budget {budget} B",
                    bm=config.bm, bn=config.bn, bk=eff_bk,
                    bytes=bcast, budget=budget, semiring=semiring))
    return diags


# ---------------------------------------------------------------------------
# Attention / KV pages (KV005)
# ---------------------------------------------------------------------------

def validate_attn(cfg,
                  *,
                  arch: str = "flash",
                  hw: TpuTarget = V5E,
                  heads: Optional[int] = None,
                  kv_heads: Optional[int] = None,
                  pool_pages: Optional[int] = None,
                  batch: Optional[int] = None,
                  max_context: Optional[int] = None,
                  table_pages: Optional[int] = None) -> List[Diagnostic]:
    """Verify a resolved :class:`repro.tuning.attention.AttnConfig`.

    For ``arch="paged_decode"`` the ``kv_block`` *is* the pool's page
    size, so the optional pool arguments extend the check to admission
    arithmetic: ``batch`` sequences of ``max_context`` tokens must fit
    ``pool_pages`` pages and ``table_pages`` block-table slots.
    """
    diags: List[Diagnostic] = []
    q_block = int(getattr(cfg, "q_block", 0) or 0)
    kv_block = int(getattr(cfg, "kv_block", 0) or 0)
    if q_block < 1 or kv_block < 1:
        diags.append(error(
            "KV005", f"non-positive attention blocking q_block={q_block} "
            f"kv_block={kv_block}", q_block=q_block, kv_block=kv_block))
        return diags

    if heads is not None and kv_heads:
        if heads % kv_heads != 0:
            diags.append(error(
                "KV005",
                f"GQA heads {heads} not divisible by kv heads {kv_heads}",
                heads=heads, kv_heads=kv_heads))

    if arch == "paged_decode":
        from repro.tuning.attention import _PAGE_CANDIDATES  # leaf import

        page = kv_block
        if page not in _PAGE_CANDIDATES:
            diags.append(error(
                "KV005",
                f"page size {page} is outside the supported candidate "
                f"set {_PAGE_CANDIDATES} — the paged kernel streams one "
                "page per grid step and the pool granularity is tuned "
                "over exactly these", page=page,
                candidates=_PAGE_CANDIDATES))
        if pool_pages is not None and batch and max_context:
            need = batch * (-(-int(max_context) // page))
            if need > pool_pages:
                diags.append(error(
                    "KV005",
                    f"pool admission overflow: {batch} sequences x "
                    f"{max_context} tokens need {need} pages of size "
                    f"{page}, pool holds {pool_pages}",
                    pages_needed=need, pool_pages=pool_pages,
                    page=page, batch=batch, max_context=max_context))
        if table_pages is not None and max_context:
            if table_pages * page < int(max_context):
                diags.append(error(
                    "KV005",
                    f"block table covers {table_pages} x {page} = "
                    f"{table_pages * page} tokens < max context "
                    f"{max_context}", table_pages=table_pages,
                    page=page, max_context=max_context))
    else:
        if kv_block % hw.lane != 0:
            diags.append(error(
                "KV005",
                f"flash kv_block {kv_block} is not a multiple of the "
                f"lane width {hw.lane}", kv_block=kv_block, lane=hw.lane))
    return diags


def validate_paged_dispatch(*, q_shape: Sequence[int], page: int,
                            n_heads: int, kv_heads: int
                            ) -> List[Diagnostic]:
    """The ``paged_attention`` call-site checks (shape/geometry only —
    lengths are traced values the verifier never sees)."""
    diags: List[Diagnostic] = []
    q_shape = tuple(int(d) for d in q_shape)
    if len(q_shape) != 4 or q_shape[1] != 1:
        diags.append(error(
            "KV005",
            f"paged decode attention takes q of shape (B, 1, H, D), got "
            f"{q_shape}", q_shape=q_shape))
    if page < 1:
        diags.append(error("KV005", f"non-positive page size {page}",
                           page=page))
    if kv_heads and n_heads % kv_heads != 0:
        diags.append(error(
            "KV005",
            f"GQA heads {n_heads} not divisible by kv heads {kv_heads}",
            heads=n_heads, kv_heads=kv_heads))
    return diags


# ---------------------------------------------------------------------------
# Distributed schedules (DIST004)
# ---------------------------------------------------------------------------

def validate_dist(schedule: str,
                  mesh: Union[Tuple[int, int, int], Dict[str, int]],
                  shapes: Tuple[int, int, int],
                  *,
                  b_block: int = 0,
                  scale_rows: int = 0) -> List[Diagnostic]:
    """Verify a distributed GEMM's geometry before the shard_map traces.

    ``mesh`` is ``(dp, tp, pods)`` or a dict with those keys; ``shapes``
    the global ``(m, n, k)``.  ``b_block`` is the weight's per-tile
    scale block (its rows ride the ring in k-chunks, so it must divide
    the chunk); ``scale_rows`` the scale tensor's leading dim (2.5-D
    meshes additionally split it over pods).  ``m`` may be ragged — the
    dispatch pads it to a ``dp`` multiple, so it is *not* checked.
    """
    from repro.core.distributed import SCHEDULES, _RING_SCHEDULES

    diags: List[Diagnostic] = []
    if isinstance(mesh, dict):
        dp = int(mesh.get("dp", 1))
        tp = int(mesh.get("tp", 1))
        pods = int(mesh.get("pods", 1))
    else:
        dp, tp, pods = (int(x) for x in mesh)
    m, n, k = (int(x) for x in shapes)

    if schedule not in SCHEDULES + ("auto",):
        diags.append(error(
            "DIST004", f"unknown schedule {schedule!r} (valid: "
            f"{SCHEDULES + ('auto',)})", schedule=schedule))
        return diags
    if min(dp, tp, pods) < 1:
        diags.append(error(
            "DIST004", f"non-positive mesh axis dp={dp} tp={tp} "
            f"pods={pods}", dp=dp, tp=tp, pods=pods))
        return diags
    if n % tp != 0:
        diags.append(error(
            "DIST004", f"n={n} does not divide over tp={tp}",
            n=n, tp=tp, schedule=schedule))
    if k % (tp * pods) != 0:
        diags.append(error(
            "DIST004", f"k={k} does not divide over tp*pods={tp * pods}",
            k=k, tp=tp, pods=pods, schedule=schedule))
    elif b_block and (schedule in _RING_SCHEDULES or schedule == "auto"):
        kchunk = k // (tp * pods)
        if kchunk % b_block != 0:
            diags.append(error(
                "DIST004",
                f"per-tile scale block {b_block} does not divide the "
                f"ring k-chunk {kchunk} — a rotated chunk would carry a "
                "fractional scale row", b_block=b_block, kchunk=kchunk,
                schedule=schedule))
        if pods > 1 and scale_rows and scale_rows % pods != 0:
            diags.append(error(
                "DIST004",
                f"per-tile scale rows {scale_rows} do not split over "
                f"pods={pods}", scale_rows=scale_rows, pods=pods))
    return diags


# ---------------------------------------------------------------------------
# Persisted tuning-cache entries (the `cache lint` mode)
# ---------------------------------------------------------------------------

def validate_cache_entry(key: str, entry) -> List[Diagnostic]:
    """Verify one persisted :class:`repro.tuning.cache.CacheEntry`
    against the current schema and budgets.

    GEMM keys re-run the tag + VMEM checks under the key's own hardware
    target and (possibly composite) dtype; attention keys check the
    order marker and page-candidate membership.  Unknown targets are
    flagged as warnings (a fleet cache may carry sections this build
    doesn't know), structural damage as errors.
    """
    diags: List[Diagnostic] = []
    parts = key.split("/")
    is_attn = len(parts) >= 2 and parts[1].startswith("attn.")

    if int(entry.bm) < 1 or int(entry.bn) < 1 or int(entry.bk) < 1:
        diags.append(error(
            "VMEM001", f"non-positive tile ({entry.bm}, {entry.bn}, "
            f"{entry.bk}) in cache entry", key=key))
        return diags

    if is_attn:
        if len(parts) != 5:
            diags.append(error(
                "TAG002", f"malformed attention cache key {key!r}",
                key=key))
            return diags
        if entry.order != _ATTN_ORDER:
            diags.append(error(
                "TAG002", f"attention key with order={entry.order!r} "
                f"(want 'attn')", key=key, order=entry.order))
        arch = parts[1][len("attn."):]
        from repro.tuning.attention import AttnConfig

        cfg = AttnConfig(q_block=int(entry.bm), kv_block=int(entry.bn))
        hw = _target_by_name(parts[0]) or V5E
        diags.extend(validate_attn(cfg, arch=arch, hw=hw))
        return diags

    if len(parts) != 6:
        diags.append(error(
            "TAG002", f"malformed GEMM cache key {key!r} (want "
            "hw/dtype/semiring/tag/layout/shape)", key=key))
        return diags
    hw_name, dtype_str, semiring, tag, layout, _shape = parts
    hw = _target_by_name(hw_name)
    if hw is None:
        diags.append(warning(
            "VMEM001", f"unknown hardware target {hw_name!r} — VMEM "
            "budget not checked", key=key, hw=hw_name))
        hw = V5E
    if entry.order not in _VALID_ORDERS:
        diags.append(error(
            "TAG002", f"unknown loop order {entry.order!r}", key=key,
            order=entry.order))
    dtype_a = dtype_b = None
    dtype = dtype_str
    if "w_" in dtype_str:            # composite quant key: "int8w_bf16a"
        w_part, a_part = dtype_str.split("w_", 1)
        dtype_b = w_part
        dtype = a_part[:-1] if a_part.endswith("a") else a_part
        dtype_a = dtype if _is_int8(dtype) else None
    try:
        cfg = TileConfig(bm=int(entry.bm), bn=int(entry.bn),
                         bk=int(entry.bk), order=entry.order)
        diags.extend(validate_program(
            tag, cfg, hw, dtype=dtype, dtype_b=dtype_b, dtype_a=dtype_a,
            semiring=semiring))
    except (TypeError, ValueError) as e:
        diags.append(error(
            "TAG002", f"cache entry fails to validate structurally: {e}",
            key=key))
    if layout not in ("nn", "nt", "tn", "tt"):
        diags.append(error(
            "TAG002", f"unknown layout {layout!r}", key=key,
            layout=layout))
    return diags

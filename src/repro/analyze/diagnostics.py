"""Diagnostic records and the single validation error type.

Every constraint the verifier checks has a stable code (``VMEM001``,
``TAG002``, ...) so tests, dashboards and the ``analyze.violations_total``
counter can name the invariant that broke, not just that *something*
did.  Codes are append-only — retiring one would silently un-gate the
constraint it named.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Mapping, Sequence

# code -> one-line invariant (the authoritative table; docs/ANALYZE.md
# mirrors it with the paper/equation references).
CODES: Dict[str, str] = {
    "VMEM001": "tile VMEM footprint (double-buffered streams + "
               "accumulators + program residents) must fit "
               "vmem_fraction * hw.vmem_bytes (paper Eq. 9)",
    "TAG002": "program tag must parse and round-trip through "
              "program_from_tag / program_tag",
    "QNT003": "quantized dtype chain must be legal (int8 operands need a "
              "dequant drain stage; int8 activations need int8 weights) "
              "and per-tile scale blocks must be lane-aligned and "
              "mutually consistent",
    "DIST004": "distributed schedule geometry must divide exactly "
               "(n over tp, k over tp*pods, per-tile blocks over the "
               "ring k-chunk)",
    "KV005": "KV page geometry and pool admission arithmetic must hold "
             "(positive lane-friendly pages, GQA head divisibility, "
             "enough pages/table slots for the admitted context)",
}

SEVERITIES = ("error", "warning")


@dataclasses.dataclass(frozen=True)
class Diagnostic:
    """One named constraint violation (or advisory).

    ``context`` carries the numbers that made the check fail — shapes,
    budgets, block sizes — as plain values so reports serialize without
    jax in the loop.
    """

    code: str
    severity: str
    message: str
    context: Mapping = dataclasses.field(default_factory=dict)

    def __post_init__(self):
        if self.code not in CODES:
            raise ValueError(f"unknown diagnostic code {self.code!r} "
                             f"(known: {sorted(CODES)})")
        if self.severity not in SEVERITIES:
            raise ValueError(f"unknown severity {self.severity!r}")

    def __str__(self) -> str:
        ctx = ""
        if self.context:
            ctx = " [" + ", ".join(f"{k}={v}" for k, v
                                   in sorted(self.context.items())) + "]"
        return f"{self.code} ({self.severity}): {self.message}{ctx}"

    def to_json(self) -> Dict:
        return {"code": self.code, "severity": self.severity,
                "message": self.message, "context": dict(self.context)}


def error(code: str, message: str, **context) -> Diagnostic:
    return Diagnostic(code=code, severity="error", message=message,
                      context=context)


def warning(code: str, message: str, **context) -> Diagnostic:
    return Diagnostic(code=code, severity="warning", message=message,
                      context=context)


class ProgramValidationError(ValueError):
    """A dispatch (or constructor) was rejected by the verifier.

    Carries the full diagnostic list — one raise names *every* violated
    constraint, instead of the first Pallas lowering failure naming none.

    ``fatal = True`` opts out of the kernel->XLA fallback ladder
    (``core.gemm._note_fallback`` re-raises fatal failures): a program
    that fails static validation is misconfigured, and silently serving
    it from the oracle path would hide the bug the validator exists to
    surface.
    """

    fatal = True

    def __init__(self, diagnostics: Sequence[Diagnostic]):
        self.diagnostics = tuple(diagnostics)
        lines = [str(d) for d in self.diagnostics]
        super().__init__(
            "program validation failed with "
            f"{len(lines)} diagnostic(s):\n  " + "\n  ".join(lines))

    @property
    def codes(self) -> Sequence[str]:
        return tuple(d.code for d in self.diagnostics)

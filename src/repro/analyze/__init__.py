"""Static analysis for the GEMM/attention serve stack.

Three coordinated passes, one diagnostic vocabulary:

* **Program verifier** (:mod:`repro.analyze.validate`) — checks a
  resolved (program tag, tile config, hardware) triple against the hard
  constraints the paper derives its layouts from *before* anything is
  dispatched: VMEM capacity (Eq. 9), tag-grammar round-trips, quantized
  dtype-chain legality, per-tile scale alignment, ring divisibility and
  KV page/pool arithmetic.  Violations are structured
  :class:`~repro.analyze.diagnostics.Diagnostic` records, never a Pallas
  lowering traceback.
* **Dispatch preflight** (:mod:`repro.analyze.preflight`) — the hot-path
  hook ``core.gemm`` / ``core.distributed`` / ``kvcache.paged`` call
  before launching a kernel.  Memoized per (cache key, config) so the
  steady state pays one dict lookup; failures raise a single
  :class:`~repro.analyze.diagnostics.ProgramValidationError` listing
  every diagnostic and count in ``analyze.violations_total{code}``.
* **AST lint** (:mod:`repro.analyze.lint`, ``python -m repro.analyze
  lint src/ benchmarks/``) — keeps future code from bypassing the
  registry/ledger/validator discipline (rules ``RPR001``-``RPR005``).

See docs/ANALYZE.md for the full code tables.
"""

from repro.analyze.diagnostics import (CODES, Diagnostic,
                                       ProgramValidationError)
from repro.analyze.preflight import (preflight_attn, preflight_dist,
                                     preflight_gemm, preflight_stats,
                                     reset_preflight)
from repro.analyze.validate import (validate_attn, validate_cache_entry,
                                    validate_dist, validate_program)

__all__ = [
    "CODES", "Diagnostic", "ProgramValidationError",
    "validate_program", "validate_attn", "validate_dist",
    "validate_cache_entry",
    "preflight_gemm", "preflight_dist", "preflight_attn",
    "preflight_stats", "reset_preflight",
]

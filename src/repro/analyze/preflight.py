"""Dispatch preflight: run the verifier once per resolved plan.

``core.gemm``, ``core.distributed`` and ``kvcache.paged`` call these
hooks after resolution and before launching a kernel.  Verdicts are
memoized per (cache key, config, operand metadata) so the steady-state
serve path pays a single dict lookup; a failing plan keeps failing from
the memo — re-dispatching it re-raises the same
:class:`~repro.analyze.diagnostics.ProgramValidationError` without
re-running the checks.

Fresh violations are counted in ``analyze.violations_total{code}`` so a
fleet can alert on validator rejections without scraping tracebacks.
"""

from __future__ import annotations

import threading
from typing import Dict, Optional, Sequence, Tuple

from repro.analyze.diagnostics import Diagnostic, ProgramValidationError
from repro.analyze import validate as _v

_LOCK = threading.Lock()
# memo key -> None (plan passed) | ProgramValidationError (plan rejected)
_VERDICTS: Dict[Tuple, Optional[ProgramValidationError]] = {}
_STATS = {"validated": 0, "hits": 0}


def _dtype_token(dtype) -> Optional[str]:
    if dtype is None:
        return None
    if isinstance(dtype, str):
        return dtype
    import jax.numpy as jnp

    return jnp.dtype(dtype).name


def _check(memo_key: Tuple, run) -> None:
    """Memoized verdict for ``memo_key``; ``run`` produces diagnostics."""
    with _LOCK:
        if memo_key in _VERDICTS:
            _STATS["hits"] += 1
            verdict = _VERDICTS[memo_key]
            if verdict is not None:
                raise verdict
            return
    # Validate outside the lock — the checks are pure and cheap, and a
    # racing duplicate just writes the same verdict twice.
    diags: Sequence[Diagnostic] = run()
    errors = [d for d in diags if d.severity == "error"]
    verdict = ProgramValidationError(errors) if errors else None
    if errors:
        _count(d.code for d in errors)
    with _LOCK:
        _STATS["validated"] += 1
        _VERDICTS[memo_key] = verdict
    if verdict is not None:
        raise verdict


def _count(codes) -> None:
    try:
        from repro.obs import get_metrics

        counter = get_metrics().counter(
            "analyze.violations_total",
            "programs rejected by the dispatch preflight, by diagnostic "
            "code")
        for code in codes:
            counter.labels(code=code).inc()
    except Exception:  # repro: noqa RPR004 -- metrics must never gate dispatch
        pass


def preflight_gemm(key: str, tag: str, config, hw, *, dtype,
                   dtype_b=None, dtype_a=None,
                   semiring: str = "plus_times",
                   scale_block: int = 0, act_block: int = 0) -> None:
    """Verify a resolved GEMM plan; raise ``ProgramValidationError``.

    ``key`` is the registry resolution key (already encodes hw, dtype,
    tag, layout and shape bucket), so (key, tile, scale blocks) pins the
    verdict.
    """
    memo_key = ("gemm", key, tag,
                (config.bm, config.bn, config.bk, config.order),
                _dtype_token(dtype), _dtype_token(dtype_b),
                _dtype_token(dtype_a), semiring, scale_block, act_block)
    _check(memo_key, lambda: _v.validate_program(
        tag, config, hw, dtype=dtype, dtype_b=dtype_b, dtype_a=dtype_a,
        semiring=semiring, scale_block=scale_block, act_block=act_block))


def preflight_dist(schedule: str, mesh: Tuple[int, int, int],
                   shapes: Tuple[int, int, int], *, b_block: int = 0,
                   scale_rows: int = 0) -> None:
    """Verify distributed GEMM geometry before the shard_map traces."""
    mesh = tuple(int(x) for x in mesh)
    shapes = tuple(int(x) for x in shapes)
    memo_key = ("dist", schedule, mesh, shapes, int(b_block),
                int(scale_rows))
    _check(memo_key, lambda: _v.validate_dist(
        schedule, mesh, shapes, b_block=b_block, scale_rows=scale_rows))


def preflight_attn(q_shape: Sequence[int], page: int, n_heads: int,
                   kv_heads: int) -> None:
    """Verify paged-attention call geometry (shapes, page, GQA)."""
    q_shape = tuple(int(d) for d in q_shape)
    memo_key = ("attn", q_shape, int(page), int(n_heads), int(kv_heads))
    _check(memo_key, lambda: _v.validate_paged_dispatch(
        q_shape=q_shape, page=page, n_heads=n_heads, kv_heads=kv_heads))


def preflight_stats() -> Dict[str, int]:
    """Copy of the memo counters (``validated`` fresh runs, ``hits``)."""
    with _LOCK:
        return dict(_STATS)


def reset_preflight() -> None:
    """Drop all memoized verdicts and zero the counters (tests)."""
    with _LOCK:
        _VERDICTS.clear()
        _STATS["validated"] = 0
        _STATS["hits"] = 0

"""Serving example: batched requests, greedy + sampled, across families.

  PYTHONPATH=src python examples/serve_lm.py
"""

import jax
import numpy as np

from repro.configs import get_reduced
from repro.models import model as M
from repro.serve.engine import Request, ServeEngine


def main():
    for arch in ("stablelm-1.6b", "mamba2-370m", "zamba2-7b"):
        cfg = get_reduced(arch)
        params = M.init_params(cfg, jax.random.PRNGKey(0))
        eng = ServeEngine(params, cfg, batch_size=2, max_len=40)
        rng = np.random.RandomState(0)
        for uid in range(2):
            eng.submit(Request(uid=uid,
                               prompt=rng.randint(0, cfg.vocab_size, 12),
                               max_new_tokens=6,
                               temperature=0.0 if uid == 0 else 0.7))
        done = eng.run()
        outs = {u: r.generated for u, r in done.items()}
        print(f"{arch:16s} greedy={outs[0]} sampled={outs[1]}")


if __name__ == "__main__":
    main()

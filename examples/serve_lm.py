"""Serving example: batched requests, greedy + sampled, across families.

  PYTHONPATH=src python examples/serve_lm.py
  PYTHONPATH=src python examples/serve_lm.py --quantize int8
  PYTHONPATH=src python examples/serve_lm.py --quantize w8a8

``--quantize int8`` demonstrates the weight-quantized serve path:
load (init stands in for a checkpoint restore) -> ``quantize_params``
(every ca_matmul-routed projection becomes an int8 QTensor with fp32
per-channel scales) -> engine startup warmup (the kernel-config registry
plans the ``int8w_*``/dequant-fused variants) -> generate.  The int8
bytes are what streams from HBM; the dequant runs inside the GEMM drain
(see docs/QUANT.md).

``--quantize w8a8`` additionally quantizes activations: the engine runs
a startup calibration pass over sample traffic, attaches static a-scales
to every projection, and serves through the int8xint8 ("ab") kernel —
the MXU's 2x int8 compute rate on top of the byte win
(``int8w_int8a`` cache keys).

``--chaos`` serves a 4-request queue under a deterministic
:class:`repro.runtime.fault.FaultPlan` — one fatal kernel failure (fails
exactly one request), one recoverable kernel failure (re-dispatched on
the XLA oracle, ``gemm.fallback_total``), one NaN decode step (walks the
quant degradation ladder, ``serve.degraded_total``), and one slow decode
step.  Statuses print per request; pair with ``--metrics`` to see the
fault counters (see docs/ROBUSTNESS.md).

``--trace trace.jsonl`` writes Chrome-trace-event spans (warmup,
calibration, per-request prefill/decode) — load the file in Perfetto or
chrome://tracing.  ``--metrics`` prints the engine's metrics report
(TTFT/TPOT histograms, prefill/decode split, tokens/s, plan sources) and
enables the GEMM ledger so the report includes achieved-vs-planned
bytes per serve step (see docs/OBSERVABILITY.md).
"""

import argparse

import jax
import numpy as np

from repro.configs import get_reduced
from repro.models import common as cm
from repro.models import model as M
from repro.obs import enable_tracing, flush
from repro.obs.ledger import get_ledger
from repro.quant import QuantConfig
from repro.runtime.fault import FaultPlan
from repro.serve.engine import Request, ServeEngine


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--quantize", choices=["none", "int8", "w8a8"],
                    default="none",
                    help="weight-quantize the serve params (int8 payload, "
                         "fp32 per-channel scales, drain-fused dequant); "
                         "w8a8 additionally calibrates static activation "
                         "scales and serves int8xint8")
    ap.add_argument("--trace", nargs="?", const="trace.jsonl", default=None,
                    metavar="PATH",
                    help="write Perfetto-loadable trace spans to PATH "
                         "(default trace.jsonl)")
    ap.add_argument("--metrics", action="store_true",
                    help="enable the GEMM ledger and print the metrics "
                         "report after serving")
    ap.add_argument("--chaos", action="store_true",
                    help="serve a 4-request queue under a deterministic "
                         "FaultPlan (fatal kernel, recoverable kernel, "
                         "NaN decode step, slow decode step) and print "
                         "per-request statuses; pair with --quantize so "
                         "the NaN triggers the degradation ladder")
    ap.add_argument("--archs", nargs="+",
                    default=["stablelm-1.6b", "mamba2-370m", "zamba2-7b"],
                    help="reduced configs to serve")
    args = ap.parse_args(argv)

    if args.trace:
        print(f"# tracing to {enable_tracing(args.trace)}")
    if args.metrics:
        get_ledger().enable()

    for arch in args.archs:
        cfg = get_reduced(arch)
        params = M.init_params(cfg, jax.random.PRNGKey(0))
        note = ""
        if args.quantize != "none":
            dense_bytes = sum(int(np.asarray(v).nbytes)
                              for v in params.values())
            params = cm.quantize_params(params, qconfig=QuantConfig())
            q_bytes = sum(v.nbytes if hasattr(v, "nbytes")
                          else int(np.asarray(v).nbytes)
                          for v in params.values())
            note = f" int8w params={q_bytes / 1e6:.2f}MB" \
                   f" ({q_bytes / dense_bytes:.2f}x of dense)"
        eng = ServeEngine(params, cfg, batch_size=2, max_len=40,
                          quantize_activations=(args.quantize == "w8a8"))
        if args.quantize != "none":
            pat = "int8w_int8a" if args.quantize == "w8a8" else "int8w_"
            n_q = sum(1 for k in eng.gemm_plan_sources if pat in k)
            note += f" quant-plans={n_q}"
            if args.quantize == "w8a8":
                note += f" calib-sites={len(eng.calibration_sites)}"
        rng = np.random.RandomState(0)
        if args.chaos:
            # Deterministic chaos: dispatch 0 (request 0's first GEMM) is
            # a fatal kernel failure — exactly that request fails;
            # dispatch 1 (request 1) is recoverable — re-dispatched on
            # the XLA oracle; decode step 5 (request 2's first) goes NaN
            # — the quant ladder degrades and retries; decode step 15
            # (request 3's first) runs slow.
            plan = FaultPlan(kernel_fatal_at=(0,), kernel_fail_at=(1,),
                             nan_decode_at=(5,), slow_decode_at={15: 0.05})
            for uid in range(4):
                eng.submit(Request(uid=uid,
                                   prompt=rng.randint(0, cfg.vocab_size, 12),
                                   max_new_tokens=6))
            with plan:
                done = eng.run()
            stat = " ".join(
                f"req{u}={r.status}"
                + (f"({r.quant_level})" if r.status == "degraded" else "")
                for u, r in sorted(done.items()))
            print(f"{arch:16s} chaos: {stat} "
                  f"injected={sorted(plan.injected)}{note}")
        else:
            for uid in range(2):
                eng.submit(Request(uid=uid,
                                   prompt=rng.randint(0, cfg.vocab_size, 12),
                                   max_new_tokens=6,
                                   temperature=0.0 if uid == 0 else 0.7))
            done = eng.run()
            outs = {u: r.generated for u, r in done.items()}
            print(f"{arch:16s} greedy={outs[0]} sampled={outs[1]}{note}")
        if args.metrics:
            print(f"--- metrics ({arch}) ---")
            print(eng.metrics_report())
    if args.trace:
        flush()
        print(f"# trace written to {args.trace}")


if __name__ == "__main__":
    main()

"""Distributed CA-GEMM demo: all three schedules on forced host devices.

Run the paper's chain-vs-broadcast comparison at cluster scale: the ring
(PE-chain analog) and all-gather (broadcast analog) schedules compute the
same product; the artifact is the collective profile, printed from the
compiled HLO of each.

  PYTHONPATH=src python examples/distributed_gemm.py
"""

import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import dist_matmul, estimate_cost
from repro.launch import hlo_analysis as H


def main():
    from repro.launch.mesh import make_mesh_compat
    mesh = make_mesh_compat((2, 4), ("data", "model"))
    rng = np.random.RandomState(0)
    a = jnp.asarray(rng.randn(256, 512), jnp.float32)
    b = jnp.asarray(rng.randn(512, 384), jnp.float32)
    want = np.asarray(a) @ np.asarray(b)

    for sched in ("allgather", "ring"):
        f = jax.jit(lambda x, y, s=sched: dist_matmul(x, y, mesh, schedule=s))
        got = f(a, b)
        comp = f.lower(a, b).compile()
        cost = H.analyze_hlo_text(comp.as_text())
        model = estimate_cost(sched, 256, 384, 512, 4, 2, 4)
        ok = np.allclose(np.asarray(got), want, atol=1e-3)
        print(f"{sched:10s} correct={ok}  "
              f"collectives={cost.coll_counts}  "
              f"hlo_coll_bytes={cost.coll_bytes:.2e}  "
              f"(model {model.comm_bytes:.2e})")


if __name__ == "__main__":
    main()

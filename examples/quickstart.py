"""Quickstart: the paper's model + kernel in five minutes.

1. Solve the I/O-optimal tile plan for a GEMM (paper Eqs. 5-9 on TPU
   constants).
2. Run the Pallas CA-MMM kernel (interpret mode on CPU) and check it
   against the oracle.
3. Show the distributed schedule the cost model picks per mesh shape.

  PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (V5E, arithmetic_intensity_ops_per_byte,
                        choose_schedule, io_volume_elements,
                        io_lower_bound_elements, solve_tile_config)
from repro.kernels import ca_mmm_any


def main():
    # --- 1. the model ----------------------------------------------------
    m = n = k = 16384
    for dt in (jnp.bfloat16, jnp.float32, jnp.int8):
        dt = jnp.dtype(dt)
        t = solve_tile_config(m, n, k, dtype_in=dt)
        q = io_volume_elements(m, n, k, t.bm, t.bn) * dt.itemsize
        lb = io_lower_bound_elements(m, n, k,
                                     int(0.75 * V5E.vmem_bytes) // 4)
        ai = arithmetic_intensity_ops_per_byte(t.bm, t.bn, dt.itemsize)
        print(f"{dt.name:9s} tile=({t.bm:4d},{t.bn:4d},{t.bk:4d})  "
              f"VMEM={t.vmem_bytes/2**20:5.1f}MiB  AI={ai:6.0f} Op/B  "
              f"Q={q/1e9:6.1f} GB  (lower bound {lb*dt.itemsize/1e9:.1f} GB)")

    # --- 2. the kernel (validated against the oracle) ---------------------
    rng = np.random.RandomState(0)
    a = jnp.asarray(rng.randn(512, 384), jnp.float32)
    b = jnp.asarray(rng.randn(384, 256), jnp.float32)
    c = ca_mmm_any(a, b, interpret=True)
    err = float(jnp.max(jnp.abs(c - a @ b)))
    print(f"\nPallas CA-MMM (interpret) vs oracle: max|err| = {err:.2e}")

    # --- 3. the distributed schedule --------------------------------------
    print("\nschedule chosen by the Eq. 6 cost model (m=n=k=16384, bf16):")
    for dp, tp, pods in ((16, 16, 1), (4, 64, 1), (16, 16, 2)):
        c = choose_schedule(16384, 16384, 16384, 2, dp, tp, pods)
        print(f"  mesh dp={dp:3d} tp={tp:3d} pods={pods}:  {c.schedule:10s}"
              f"  comm={c.comm_bytes/1e6:8.1f} MB/dev  "
              f"t={c.time_s*1e3:.2f} ms")


if __name__ == "__main__":
    main()

"""End-to-end driver: train an LM for a few hundred steps on the
synthetic pipeline, with checkpointing and an injected crash + restart
halfway — the fault-tolerance path exercised for real.

  PYTHONPATH=src python examples/train_lm.py                # ~25M, fast
  PYTHONPATH=src python examples/train_lm.py --scale 100m   # the full
      ~100M GPT-2-small-class deliverable config (12L x 768; ~57 s/step
      on this 1-core CPU container — sized for accelerator hosts)
"""

import argparse
import dataclasses
import tempfile

from repro.configs import get_config
from repro.launch.train import run_training
import repro.configs as C


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--scale", choices=("25m", "100m"), default="25m")
    args = ap.parse_args()

    base = get_config("stablelm-1.6b")
    if args.scale == "100m":
        # GPT-2-small-class: 12L x d_model=768
        cfg = dataclasses.replace(
            base, n_layers=12, d_model=768, n_heads=12, n_kv_heads=12,
            d_ff=2048, vocab_size=32000, compute_dtype="float32",
            q_chunk=64, kv_chunk=128)
        args.seq_len = max(args.seq_len, 128)
    else:
        cfg = dataclasses.replace(
            base, n_layers=8, d_model=384, n_heads=6, n_kv_heads=6,
            d_ff=1024, vocab_size=16384, compute_dtype="float32",
            q_chunk=64, kv_chunk=64)
    n = cfg.n_params()
    name = f"stablelm-{args.scale}"
    print(f"training {name}: {n/1e6:.0f}M params, "
          f"{args.steps} steps @ seq={args.seq_len} batch={args.global_batch}")

    # register as a transient arch so run_training can find it
    mod = dataclasses.replace(cfg, name=name)
    C._MODULES[name] = type(
        "M", (), {"CONFIG": mod, "reduced": staticmethod(lambda: mod)})

    with tempfile.TemporaryDirectory() as ckpt:
        half = args.steps // 2
        try:
            run_training(name, args.steps, seq_len=args.seq_len,
                         global_batch=args.global_batch, lr=1e-3,
                         ckpt_dir=ckpt, ckpt_every=max(half // 2, 1),
                         fail_at=half, log_every=25)
        except RuntimeError as e:
            print(f"!! {e} — restarting from last checkpoint")
        _, losses = run_training(
            name, args.steps, seq_len=args.seq_len,
            global_batch=args.global_batch, lr=1e-3, ckpt_dir=ckpt,
            ckpt_every=max(half // 2, 1), resume=True, log_every=25)
    print(f"loss: {losses[0]:.3f} -> {losses[-1]:.3f} "
          f"({'DECREASED' if losses[-1] < losses[0] else 'no decrease'})")


if __name__ == "__main__":
    main()
